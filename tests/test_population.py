"""Population search, energy memoization, vectorized testing, build LRU."""

import inspect
import sys

import numpy as np
import pytest

from repro.core import (CachedEnergy, CostModelEnergy, FaultInjector,
                        InputSpec, Instr, Kind, LRUCache, MutationPolicy,
                        Schedule, SearchSpace, SipKernel, TuneConfig,
                        WallClockEnergy, anneal, population_anneal,
                        probabilistic_test)
from repro.core.ir import Program


def make_latency_program(n_steps=6):
    instrs = []
    for s in range(n_steps):
        instrs.append(Instr(name=f"ld{s}", kind=Kind.MEM, inputs=(),
                            outputs=(f"x{s}",), fn=lambda env: {},
                            buffer=f"B{s}", bytes=1 << 16))
        instrs.append(Instr(name=f"mm{s}", kind=Kind.COMPUTE, inputs=(f"x{s}",),
                            outputs=(f"y{s}",), fn=lambda env: {},
                            flops=1 << 18))
    return Program(instrs)


def _setup(n_steps=6):
    p = make_latency_program(n_steps)
    policy = MutationPolicy(space=SearchSpace(), program_for=lambda s: p)
    energy = CostModelEnergy(program_for=lambda s: p)
    return p, policy, energy


class TestPopulationAnneal:
    def test_single_chain_bit_identical_to_anneal(self):
        """chains=1: same seed => identical trajectory, not just same best."""
        _, policy, energy = _setup()
        ref = anneal(Schedule(), energy, policy.propose, seed=7, cooling=1.05)
        pop = population_anneal(Schedule(), energy, policy.propose, chains=1,
                                seed=7, cooling=1.05, memoize=False)
        got = pop.chains[0]
        assert got.best.order == ref.best.order
        assert got.best_raw == ref.best_raw
        assert got.evals == ref.evals
        assert got.history == ref.history

    def test_single_chain_identical_with_memoization(self):
        """Memoizing a deterministic energy never changes search results."""
        _, policy, energy = _setup()
        ref = anneal(Schedule(), energy, policy.propose, seed=3, cooling=1.05)
        pop = population_anneal(Schedule(), energy, policy.propose, chains=1,
                                seed=3, cooling=1.05, memoize=True)
        assert pop.chains[0].best.order == ref.best.order
        assert pop.chains[0].best_raw == ref.best_raw

    def test_seeded_determinism(self):
        _, policy, energy = _setup()
        a = population_anneal(Schedule(), energy, policy.propose, chains=4,
                              seed=11, cooling=1.05, exchange_every=8)
        b = population_anneal(Schedule(), energy, policy.propose, chains=4,
                              seed=11, cooling=1.05, exchange_every=8)
        assert a.best.order == b.best.order
        assert a.best_energy == b.best_energy
        assert a.exchanges == b.exchanges
        assert [c.best_energy for c in a.chains] == \
            [c.best_energy for c in b.chains]

    def test_population_improves_and_is_legal(self):
        p, policy, energy = _setup()
        pop = population_anneal(Schedule(), energy, policy.propose, chains=4,
                                seed=0, cooling=1.05, exchange_every=8)
        assert pop.improvement > 0
        assert p.is_legal(pop.best.order)
        # the winning chain's best is at least as good as every chain's
        assert all(pop.best_energy <= c.best_energy for c in pop.chains)

    def test_exchange_migrates_states(self):
        _, policy, energy = _setup()
        pop = population_anneal(Schedule(), energy, policy.propose, chains=4,
                                seed=0, cooling=1.05, exchange_every=4)
        assert pop.exchanges > 0
        off = population_anneal(Schedule(), energy, policy.propose, chains=4,
                                seed=0, cooling=1.05, exchange_every=0)
        assert off.exchanges == 0

    def test_shared_cache_across_chains(self):
        """All K chains start from x0: K-1 of the initial evals are hits."""
        _, policy, energy = _setup()
        pop = population_anneal(Schedule(), energy, policy.propose, chains=4,
                                seed=0, cooling=1.1)
        stats = pop.cache_stats
        assert stats is not None
        assert stats["hits"] >= 3                       # the shared x0 evals
        assert stats["hits"] + stats["misses"] == pop.evals

    def test_bad_args_rejected(self):
        _, policy, energy = _setup(2)
        with pytest.raises(ValueError, match="chains"):
            population_anneal(Schedule(), energy, policy.propose, chains=0)
        with pytest.raises(ValueError, match="ladder"):
            population_anneal(Schedule(), energy, policy.propose, ladder=0.5)


class TestCachedEnergy:
    def test_hit_miss_accounting(self):
        calls = {"n": 0}

        def energy(s):
            calls["n"] += 1
            return 1.0 + len(s.knobs)

        ce = CachedEnergy(energy)
        a, b = Schedule(knobs={"bm": 1}), Schedule(knobs={"bm": 2})
        assert ce(a) == ce(a) == ce(a)
        ce(b)
        assert calls["n"] == 2                 # one real eval per signature
        assert ce.stats() == {"hits": 2, "misses": 2, "size": 2}

    def test_anneal_surfaces_cache_stats(self):
        _, policy, energy = _setup()
        res = anneal(Schedule(), CachedEnergy(energy), policy.propose,
                     seed=0, cooling=1.1)
        assert res.cache_stats is not None
        assert res.cache_stats["hits"] + res.cache_stats["misses"] == res.evals

    def test_bounded(self):
        ce = CachedEnergy(lambda s: float(len(s.knobs)), maxsize=2)
        for i in range(5):
            ce(Schedule(knobs={f"k{j}": 1 for j in range(i)}))
        assert ce.stats()["size"] <= 2


class TestCacheHitRate:
    """cache_stats surfaces the memo hit rate as a ratio, windowed per
    tune round (obs PR satellite)."""

    def test_population_cache_stats_hit_rate(self):
        _, policy, energy = _setup()
        pop = population_anneal(Schedule(), energy, policy.propose, chains=4,
                                seed=0, cooling=1.1)
        stats = pop.cache_stats
        assert stats is not None
        assert 0.0 <= stats["hit_rate"] <= 1.0
        assert stats["hit_rate"] == pytest.approx(
            stats["hits"] / (stats["hits"] + stats["misses"]))

    def test_anneal_cache_stats_hit_rate(self):
        _, policy, energy = _setup()
        res = anneal(Schedule(), CachedEnergy(energy), policy.propose,
                     seed=0, cooling=1.1)
        stats = res.cache_stats
        assert 0.0 <= stats["hit_rate"] <= 1.0
        assert stats["hit_rate"] == pytest.approx(
            stats["hits"] / max(stats["hits"] + stats["misses"], 1))

    def test_delta_stats_zero_window(self):
        from repro.core.energy import delta_stats

        assert delta_stats({}, {})["hit_rate"] == 0.0
        d = delta_stats({"hits": 5, "misses": 5, "size": 5},
                        {"hits": 5, "misses": 5, "size": 5})
        assert d == {"hits": 0, "misses": 0, "size": 0, "hit_rate": 0.0}
        d = delta_stats({"hits": 2, "misses": 8},
                        {"hits": 5, "misses": 9})
        assert d["hits"] == 3 and d["misses"] == 1
        assert d["hit_rate"] == pytest.approx(0.75)

    def test_reset_stats_keeps_memo(self):
        calls = {"n": 0}

        def energy(s):
            calls["n"] += 1
            return 1.0

        ce = CachedEnergy(energy)
        s = Schedule(knobs={"bm": 1})
        ce(s), ce(s)
        assert ce.stats() == {"hits": 1, "misses": 1, "size": 1}
        ce.reset_stats()
        assert ce.stats() == {"hits": 0, "misses": 0, "size": 1}
        ce(s)                                  # memo survived the reset
        assert calls["n"] == 1
        assert ce.stats()["hits"] == 1

    def test_lru_reset_stats_keeps_entries(self):
        lru = LRUCache(maxsize=4)
        lru.get_or_build("a", lambda: 1)
        lru.get_or_build("a", lambda: 2)
        lru.reset_stats()
        assert lru.stats() == {"hits": 0, "misses": 0, "size": 1}
        assert lru.get_or_build("a", lambda: 3) == 1

    def test_tune_rounds_window_cache_stats(self):
        """Each round's cache_stats/build_cache describes that round alone:
        counters reset between rounds while the memo persists, so per-round
        hits+misses stay bounded by that round's evals."""
        from repro.kernels.rmsnorm import ops as rms_ops

        rng = np.random.default_rng(0)
        kern = rms_ops.make()
        x = rng.standard_normal((16, 32)).astype(np.float32)
        g = rng.standard_normal((32,)).astype(np.float32)
        cfg = TuneConfig(rounds=2, t_min=0.25, cooling=1.25, step_samples=1,
                         final_samples=2)
        res = kern.tune([x, g], cfg)
        assert len(res) == 2
        sig = kern.sig_str(kern.static_of(x, g))
        entries = kern.cache.entries(rms_ops.NAME, sig)
        by_round = {e.round_id: e.meta for e in entries}
        assert set(by_round) == {0, 1}
        for r, meta in by_round.items():
            cs = meta["cache_stats"]
            assert cs["hits"] + cs["misses"] == meta["evals"], \
                f"round {r} counters span more than the round"
            assert 0.0 <= cs["hit_rate"] <= 1.0
            bc = meta["build_cache"]
            assert 0.0 <= bc["hit_rate"] <= 1.0
            assert bc["misses"] <= meta["evals"] + 1   # +1: final test build
        # round 1 revisits round 0's memoized schedules (same x0 at least)
        assert by_round[1]["cache_stats"]["hits"] >= 1


class TestVectorizedTesting:
    SPECS = [InputSpec((8,))]

    def test_loop_matches_serial_batching(self):
        """batch=16 (loop mode) == batch=1: same report, same rng draws."""
        oracle = lambda x: np.asarray(x) * 2.0
        bad = FaultInjector(oracle, threshold=3.0, corruption=0.5)
        for fn in (oracle, bad):
            a = probabilistic_test(fn, oracle, self.SPECS, 200,
                                   np.random.default_rng(0), rtol=1e-3,
                                   atol=1e-3, batch=1, vectorize="loop")
            b = probabilistic_test(fn, oracle, self.SPECS, 200,
                                   np.random.default_rng(0), rtol=1e-3,
                                   atol=1e-3, batch=16, vectorize="loop")
            assert (a.passed, a.samples_run, a.first_failure, a.max_err) == \
                (b.passed, b.samples_run, b.first_failure, b.max_err)

    def test_auto_falls_back_for_numpy_callables(self):
        """FaultInjector is numpy — vmap can't trace it; auto must still
        produce the loop-mode report (same pass/fail and max_err)."""
        oracle = lambda x: np.asarray(x) * 2.0
        bad = FaultInjector(oracle, threshold=3.0, corruption=0.5)
        a = probabilistic_test(bad, oracle, self.SPECS, 500,
                               np.random.default_rng(1), rtol=1e-3, atol=1e-3,
                               vectorize="auto")
        b = probabilistic_test(bad, oracle, self.SPECS, 500,
                               np.random.default_rng(1), rtol=1e-3, atol=1e-3,
                               vectorize="loop")
        assert (a.passed, a.samples_run, a.first_failure, a.max_err) == \
            (b.passed, b.samples_run, b.first_failure, b.max_err)

    def test_auto_falls_back_when_only_oracle_is_numpy(self):
        """Regression: vmap succeeding on the candidate but raising on the
        oracle must fall back cleanly, not crash on a half-filled batch."""
        import jax.numpy as jnp

        cand = lambda x: jnp.asarray(x) * 2.0
        oracle = lambda x: np.asarray(x) * 2.0     # numpy: untraceable
        rep = probabilistic_test(cand, oracle, self.SPECS, 32,
                                 np.random.default_rng(0), vectorize="auto")
        assert rep.passed and rep.samples_run == 32

    def test_vmap_path_on_jax_callable(self):
        import jax.numpy as jnp

        f = lambda x: jnp.asarray(x) * 2.0
        rep = probabilistic_test(f, f, self.SPECS, 64,
                                 np.random.default_rng(0), vectorize="vmap")
        assert rep.passed and rep.samples_run == 64

    def test_vmap_detects_fault_like_loop(self):
        import jax.numpy as jnp

        oracle = lambda x: jnp.asarray(x) * 2.0
        bad = lambda x: jnp.asarray(x) * 2.0 + 0.5   # uniformly wrong
        v = probabilistic_test(bad, oracle, self.SPECS, 64,
                               np.random.default_rng(0), rtol=1e-3, atol=1e-3,
                               vectorize="vmap")
        l = probabilistic_test(bad, oracle, self.SPECS, 64,
                               np.random.default_rng(0), rtol=1e-3, atol=1e-3,
                               vectorize="loop")
        assert not v.passed and not l.passed
        assert v.first_failure == l.first_failure == 1

    def test_bad_args_rejected(self):
        f = lambda x: x
        with pytest.raises(ValueError, match="batch"):
            probabilistic_test(f, f, self.SPECS, 4,
                               np.random.default_rng(0), batch=0)
        with pytest.raises(ValueError, match="vectorize"):
            probabilistic_test(f, f, self.SPECS, 4,
                               np.random.default_rng(0), vectorize="nope")


class TestWallClockEnergy:
    def test_warmup_zero_regression(self):
        """warmup=0 used to hit an UnboundLocalError inside the catch-all and
        silently report FAILED for a perfectly good kernel."""
        e = WallClockEnergy(build=lambda s: (lambda x: x * 2.0),
                            make_args=lambda: [np.ones(4, np.float32)],
                            warmup=0, iters=2)
        t = e(Schedule())
        assert np.isfinite(t) and t > 0


class TestLRUCache:
    def test_eviction_and_stats(self):
        lru = LRUCache(maxsize=2)
        assert lru.get_or_build("a", lambda: 1) == 1
        assert lru.get_or_build("b", lambda: 2) == 2
        assert lru.get_or_build("a", lambda: 99) == 1     # hit, refreshed
        lru.get_or_build("c", lambda: 3)                  # evicts b (LRU)
        assert "b" not in lru and "a" in lru and "c" in lru
        assert lru.stats() == {"hits": 1, "misses": 3, "size": 2}

    def test_maxsize_validated(self):
        with pytest.raises(ValueError, match="maxsize"):
            LRUCache(maxsize=0)


class TestTuneIntegration:
    def test_config_default_is_none_not_shared_instance(self):
        assert inspect.signature(SipKernel.tune).parameters["config"].default \
            is None

    def test_population_tune_on_gemm(self):
        from repro.kernels.gemm_fused import ops as gemm_ops
        from repro.kernels.gemm_fused import ref as gemm_ref

        rng = np.random.default_rng(0)
        kern = gemm_ops.make()
        x = rng.standard_normal((16, 32)).astype(np.float32)
        w = rng.standard_normal((32, 16)).astype(np.float32)
        cfg = TuneConfig(rounds=1, t_min=0.25, cooling=1.25, step_samples=1,
                         final_samples=4, chains=3, exchange_every=4)
        res = kern.tune([x, w], cfg)
        assert len(res) == 1 and res[0].improvement >= 0
        assert res[0].cache_stats is not None      # memoization on by default
        np.testing.assert_allclose(np.asarray(kern(x, w)),
                                   np.asarray(gemm_ref.gemm_leaky_relu(x, w)),
                                   rtol=1e-4, atol=1e-4)
        ent = kern.cache.entries(gemm_ops.NAME,
                                 kern.sig_str(kern.static_of(x, w)))
        assert ent and ent[0].meta["chains"] == 3

    def test_build_lru_shares_builds_across_gates(self):
        """step_test + final test + (implicitly) timing share one build per
        schedule: _build calls == LRU misses <= distinct schedules tested."""
        from repro.kernels.rmsnorm import ops as rms_ops

        rng = np.random.default_rng(0)
        kern = rms_ops.make()
        builds = {"n": 0}
        inner = kern._build

        def counting_build(s, **static):
            builds["n"] += 1
            return inner(s, **static)

        kern._build = counting_build
        x = rng.standard_normal((16, 32)).astype(np.float32)
        g = rng.standard_normal((32,)).astype(np.float32)
        cfg = TuneConfig(rounds=2, t_min=0.25, cooling=1.25, step_samples=1,
                         final_samples=2)
        res = kern.tune([x, g], cfg)
        evals = sum(r.evals for r in res)
        # legacy behavior was >= evals + rounds builds (step test + final
        # test each rebuilt); the LRU must do strictly better than one build
        # per energy query
        assert builds["n"] < evals + len(res)

    def test_cli_population_flags_reach_tune_config(self, monkeypatch, tmp_path):
        from repro.launch import tune

        seen = {}

        class FakeSession:
            failures: list = []

            def __init__(self, cache=None, config=None, **kw):
                seen["cfg"] = config

            def run(self, kernels=None, suite="default", verbose=False,
                    resume=False):
                return [object()]

        monkeypatch.setattr(tune, "TuningSession", FakeSession)
        base = ["--cache", str(tmp_path / "c.json")]
        tune.main(base + ["--chains", "4", "--exchange-every", "8",
                          "--no-memoize"])
        assert seen["cfg"].chains == 4
        assert seen["cfg"].exchange_every == 8
        assert seen["cfg"].memoize is False
        tune.main(base)
        assert seen["cfg"].chains == 1 and seen["cfg"].memoize is True
