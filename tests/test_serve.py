"""Serving engine tests: batched generation, greedy determinism, SIP-tuned
kernel integration on the forward path."""

import numpy as np
import pytest

from repro.models import model as M
from repro.models import modules as nn
from repro.models.config import ModelConfig
from repro.serve.engine import Engine, ServeConfig

import jax

CFG = ModelConfig(name="t", family="dense", n_layers=2, d_model=64,
                  n_heads=4, n_kv_heads=2, d_ff=128, vocab=128,
                  dtype="float32")


@pytest.fixture(scope="module")
def params():
    return nn.unwrap(M.init_lm(jax.random.PRNGKey(0), CFG.validate()))


class TestEngine:
    def test_generates_batched(self, params):
        eng = Engine(params, CFG, ServeConfig(max_len=64))
        rng = np.random.default_rng(0)
        prompts = rng.integers(0, 128, (4, 16)).astype(np.int32)
        out = eng.generate(prompts, max_new_tokens=8)
        assert out.shape == (4, 8)
        assert out.dtype == np.int32
        assert (out >= 0).all() and (out < 128).all()
        assert eng.stats["tokens_out"] == 32

    def test_greedy_deterministic(self, params):
        eng = Engine(params, CFG, ServeConfig(max_len=64))
        rng = np.random.default_rng(1)
        prompts = rng.integers(0, 128, (2, 16)).astype(np.int32)
        a = eng.generate(prompts, max_new_tokens=6)
        b = eng.generate(prompts, max_new_tokens=6)
        np.testing.assert_array_equal(a, b)

    def test_greedy_matches_forward_argmax(self, params):
        """First generated token == argmax of the forward logits at the last
        prompt position (teacher-forced consistency)."""
        import jax.numpy as jnp
        eng = Engine(params, CFG, ServeConfig(max_len=64))
        rng = np.random.default_rng(2)
        prompts = rng.integers(0, 128, (2, 16)).astype(np.int32)
        out = eng.generate(prompts, max_new_tokens=1)
        logits, _ = M.forward(params, {"tokens": jnp.asarray(prompts)}, CFG)
        want = np.argmax(np.asarray(logits[:, -1]), axis=-1)
        np.testing.assert_array_equal(out[:, 0], want)

    def test_eos_stops_early(self, params):
        eng = Engine(params, CFG, ServeConfig(max_len=64))
        rng = np.random.default_rng(3)
        prompts = rng.integers(0, 128, (2, 8)).astype(np.int32)
        first = eng.generate(prompts, max_new_tokens=1)
        eos = int(first[0, 0])
        out = eng.generate(prompts, max_new_tokens=32, eos_id=eos)
        assert out.shape[1] <= 32

    def test_temperature_sampling_varies(self, params):
        rng = np.random.default_rng(4)
        prompts = rng.integers(0, 128, (8, 8)).astype(np.int32)
        eng = Engine(params, CFG, ServeConfig(max_len=64, temperature=5.0,
                                              seed=0))
        eng2 = Engine(params, CFG, ServeConfig(max_len=64, temperature=5.0,
                                               seed=1))
        a = eng.generate(prompts, max_new_tokens=4)
        b = eng2.generate(prompts, max_new_tokens=4)
        assert not np.array_equal(a, b)


class TestSipServingIntegration:
    def test_pallas_attention_on_prefill_path(self):
        """cfg.use_pallas routes prefill through the SIP-tunable kernel and
        must match the jnp path."""
        import dataclasses
        import jax.numpy as jnp
        cfg_p = dataclasses.replace(CFG, use_pallas=True)
        params = nn.unwrap(M.init_lm(jax.random.PRNGKey(0), CFG.validate()))
        rng = np.random.default_rng(5)
        toks = jnp.asarray(rng.integers(0, 128, (2, 32)), jnp.int32)
        l_ref, _ = M.forward(params, {"tokens": toks}, CFG)
        l_pal, _ = M.forward(params, {"tokens": toks}, cfg_p)
        np.testing.assert_allclose(np.asarray(l_pal), np.asarray(l_ref),
                                   rtol=2e-4, atol=2e-4)
