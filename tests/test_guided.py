"""Guided (beyond-paper) mutation policy tests."""

import numpy as np

from repro.core import CostModelEnergy, Schedule, SearchSpace, anneal
from repro.core.guided import GuidedMutationPolicy
from repro.core.mutation import MutationPolicy

from tests.test_core_annealing import make_latency_program


class TestGuidedPolicy:
    def _setup(self, n=8):
        p = make_latency_program(n)
        program_for = lambda s: p
        energy = CostModelEnergy(program_for)
        return p, program_for, energy

    def test_proposals_stay_legal(self):
        p, program_for, _ = self._setup()
        policy = GuidedMutationPolicy(space=SearchSpace(),
                                      program_for=program_for, greed=1.0)
        rng = np.random.default_rng(0)
        s = Schedule()
        for _ in range(30):
            s2 = policy.propose(s, rng)
            if s2 is None:
                break
            assert p.is_legal(s2.order)
            s = s2

    def test_guided_at_least_as_good_as_vanilla(self):
        _, program_for, energy = self._setup()
        kw = dict(t_max=1.0, t_min=5e-3, cooling=1.05)
        rv = anneal(Schedule(), energy,
                    MutationPolicy(space=SearchSpace(),
                                   program_for=program_for).propose,
                    seed=0, **kw)
        rg = anneal(Schedule(), energy,
                    GuidedMutationPolicy(space=SearchSpace(),
                                         program_for=program_for,
                                         greed=0.5).propose,
                    seed=0, **kw)
        assert rg.best_raw <= rv.best_raw * 1.001
        assert rg.improvement > 0.1

    def test_zero_greed_is_paper_policy(self):
        """greed=0 must behave exactly like the uniform policy."""
        p, program_for, _ = self._setup(4)
        rng1 = np.random.default_rng(42)
        rng2 = np.random.default_rng(42)
        v = MutationPolicy(space=SearchSpace(), program_for=program_for)
        g = GuidedMutationPolicy(space=SearchSpace(),
                                 program_for=program_for, greed=0.0)
        s = Schedule()
        for _ in range(10):
            a = v.propose(s, rng1)
            b = g.propose(s, rng2)
            assert (a is None) == (b is None)
            if a is None:
                break
            assert a.order == b.order
            s = a
