"""Guided (beyond-paper) mutation policy tests + --guided CLI wiring."""

import sys

import numpy as np

from repro.core import (CostModelEnergy, Schedule, ScheduleCache, SearchSpace,
                        SipKernel, TuneConfig, anneal)
from repro.core.guided import GuidedMutationPolicy
from repro.core.jit import _make_policy
from repro.core.mutation import MutationPolicy

from tests.test_core_annealing import make_latency_program


class TestGuidedPolicy:
    def _setup(self, n=8):
        p = make_latency_program(n)
        program_for = lambda s: p
        energy = CostModelEnergy(program_for)
        return p, program_for, energy

    def test_proposals_stay_legal(self):
        p, program_for, _ = self._setup()
        policy = GuidedMutationPolicy(space=SearchSpace(),
                                      program_for=program_for, greed=1.0)
        rng = np.random.default_rng(0)
        s = Schedule()
        for _ in range(30):
            s2 = policy.propose(s, rng)
            if s2 is None:
                break
            assert p.is_legal(s2.order)
            s = s2

    def test_guided_at_least_as_good_as_vanilla(self):
        _, program_for, energy = self._setup()
        kw = dict(t_max=1.0, t_min=5e-3, cooling=1.05)
        rv = anneal(Schedule(), energy,
                    MutationPolicy(space=SearchSpace(),
                                   program_for=program_for).propose,
                    seed=0, **kw)
        rg = anneal(Schedule(), energy,
                    GuidedMutationPolicy(space=SearchSpace(),
                                         program_for=program_for,
                                         greed=0.5).propose,
                    seed=0, **kw)
        assert rg.best_raw <= rv.best_raw * 1.001
        assert rg.improvement > 0.1

    def test_zero_greed_is_paper_policy(self):
        """greed=0 must behave exactly like the uniform policy."""
        p, program_for, _ = self._setup(4)
        rng1 = np.random.default_rng(42)
        rng2 = np.random.default_rng(42)
        v = MutationPolicy(space=SearchSpace(), program_for=program_for)
        g = GuidedMutationPolicy(space=SearchSpace(),
                                 program_for=program_for, greed=0.0)
        s = Schedule()
        for _ in range(10):
            a = v.propose(s, rng1)
            b = g.propose(s, rng2)
            assert (a is None) == (b is None)
            if a is None:
                break
            assert a.order == b.order
            s = a


class TestGuidedFlagWiring:
    """The --guided flag must actually change the search policy (it used to
    be parsed and dropped on the floor)."""

    def _program_for(self):
        p = make_latency_program(4)
        return lambda s, **static: p

    def test_make_policy_dispatch(self):
        pf = self._program_for()
        guided = _make_policy(TuneConfig(guided=True, greed=0.7),
                              SearchSpace(), lambda s: pf(s))
        vanilla = _make_policy(TuneConfig(guided=False),
                               SearchSpace(), lambda s: pf(s))
        assert isinstance(guided, GuidedMutationPolicy)
        assert guided.greed == 0.7
        assert type(vanilla) is MutationPolicy

    def _fake_kernel(self, cache):
        pf = self._program_for()
        oracle = lambda x: np.asarray(x) * 2.0
        return SipKernel(name="fake",
                         build=lambda schedule, **static: oracle,
                         program_for=pf,
                         space_for=lambda **static: SearchSpace(),
                         oracle=oracle,
                         signature_fn=lambda x: {"n": int(x.shape[0])},
                         cache=cache)

    def test_guided_tune_runs_and_caches(self, tmp_path):
        cache = ScheduleCache(str(tmp_path / "c.json"))
        kern = self._fake_kernel(cache)
        cfg = TuneConfig(rounds=1, cooling=1.2, step_samples=0,
                         final_samples=1, guided=True, greed=1.0)
        res = kern.tune([np.ones(8, np.float32)], cfg)
        assert len(res) == 1
        assert res[0].improvement > 0       # greedy steps find the overlap
        sig = kern.sig_str({"n": 8})
        assert cache.best("fake", sig) is not None

    def test_cli_guided_flag_reaches_tune_config(self, monkeypatch, tmp_path):
        from repro.launch import tune
        seen = {}

        class FakeSession:
            failures: list = []

            def __init__(self, cache=None, config=None, **kw):
                seen["cfg"] = config

            def run(self, kernels=None, suite="default", verbose=False,
                    resume=False):
                return [object()]

        monkeypatch.setattr(tune, "TuningSession", FakeSession)
        base = ["--cache", str(tmp_path / "c.json")]
        tune.main(base + ["--guided", "--greed", "0.9"])
        assert seen["cfg"].guided is True and seen["cfg"].greed == 0.9
        tune.main(base)
        assert seen["cfg"].guided is False
