"""SIP end-to-end integration: @sip_jit tune -> cache -> deploy on real
kernels; the full paper workflow at test scale."""

import numpy as np
import pytest

from repro.core import ScheduleCache
from repro.core.jit import TuneConfig
from repro.kernels.gemm_fused import ops as gemm_ops
from repro.kernels.gemm_fused import ref as gemm_ref
from repro.kernels.rmsnorm import ops as rms_ops
from repro.kernels.rmsnorm import ref as rms_ref

RNG = np.random.default_rng(3)
QUICK = TuneConfig(rounds=1, t_min=0.25, cooling=1.25, step_samples=1,
                   final_samples=4)


class TestSipJitWorkflow:
    def test_tune_improves_and_stays_correct(self):
        kern = gemm_ops.make()
        x = RNG.standard_normal((32, 64)).astype(np.float32)
        w = RNG.standard_normal((64, 32)).astype(np.float32)
        res = kern.tune([x, w], QUICK)
        assert res[0].improvement >= 0           # never worse than baseline
        np.testing.assert_allclose(np.asarray(kern(x, w)),
                                   np.asarray(gemm_ref.gemm_leaky_relu(x, w)),
                                   rtol=1e-4, atol=1e-4)

    def test_cache_persists_across_instances(self, tmp_path):
        path = str(tmp_path / "cache.json")
        kern = gemm_ops.make(cache=ScheduleCache(path))
        x = RNG.standard_normal((16, 16)).astype(np.float32)
        w = RNG.standard_normal((16, 16)).astype(np.float32)
        kern.tune([x, w], QUICK)
        static = kern.static_of(x, w)
        # a fresh instance (new process analogue) sees the tuned schedule
        kern2 = gemm_ops.make(cache=ScheduleCache(path))
        sched = kern2.schedule_for(static)
        assert sched.order is not None or sched.knobs  # non-default entry
        np.testing.assert_allclose(np.asarray(kern2(x, w)),
                                   np.asarray(gemm_ref.gemm_leaky_relu(x, w)),
                                   rtol=1e-4, atol=1e-4)

    def test_shape_keyed_schedules(self):
        kern = gemm_ops.make()
        a = kern.static_of(np.zeros((16, 32), np.float32),
                           np.zeros((32, 16), np.float32))
        b = kern.static_of(np.zeros((32, 32), np.float32),
                           np.zeros((32, 32), np.float32))
        assert kern.sig_str(a) != kern.sig_str(b)

    def test_wallclock_energy_backend(self):
        """The paper's execution-based feedback also runs (slower, CPU)."""
        kern = rms_ops.make()
        x = RNG.standard_normal((16, 32)).astype(np.float32)
        g = RNG.standard_normal((32,)).astype(np.float32)
        cfg = TuneConfig(rounds=1, t_min=0.5, cooling=1.5, step_samples=0,
                         final_samples=2, energy="wallclock")
        res = kern.tune([x, g], cfg)
        assert np.isfinite(res[0].best_raw) and res[0].best_raw > 0
        np.testing.assert_allclose(np.asarray(kern(x, g)),
                                   np.asarray(rms_ref.rmsnorm(x, g)),
                                   rtol=1e-4, atol=1e-4)

    def test_rmsnorm_tunes(self):
        kern = rms_ops.make()
        x = RNG.standard_normal((32, 64)).astype(np.float32)
        g = RNG.standard_normal((64,)).astype(np.float32)
        res = kern.tune([x, g], QUICK)
        assert res[0].improvement >= 0
        ent = kern.cache.entries(rms_ops.NAME,
                                 kern.sig_str(kern.static_of(x, g)))
        assert ent and all(e.tests_passed for e in ent)
