"""Repo-wide test bootstrap.

This container has no network access, so optional test-only dependencies may
be absent.  When the real ``hypothesis`` package is unavailable we fall back
to the vendored deterministic stub in ``tests/_stubs`` (same API surface the
tests use, uniform numpy sampling, no shrinking).  With hypothesis installed
the stub is inert.
"""

import os
import sys

try:
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "tests",
                                    "_stubs"))
